"""Fleet configuration: simulated edge-device swarm topology and chaos.

A fleet is W workers that jointly own the step's antithetic SPSA probes
(probe-parallel data distribution, docs/fleet.md): worker w evaluates the
contiguous probe block [w*m, (w+1)*m) on the step-deterministic batch and
publishes one ledger record. The chaos knobs drive the deterministic
in-process transport (fleet/transport.py) so dropout/straggler/crash
scenarios are reproducible test fixtures, not flaky integration tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class RobustConfig:
    """Byzantine-robust commit filtering knobs (fleet/robust.py).

    The filter is a pure function of (records, accepted mask): every
    participant — coordinator, workers, the single-process reference, and
    a ledger replay — derives the bit-identical post-filter probe mask
    (docs/fleet.md, Byzantine section). All scalar math runs host-side in
    strict numpy float32, like ``engine.host_coeffs``.
    """
    # -- per-probe scalar band (fp32 lane): median-of-means center,
    #    clip/mask at k * MAD, iterated to a fixpoint (idempotence) --
    mode: str = "mask"            # "mask": reject out-of-band probes;
    #                               "clip": clip their loss-diffs to the band
    k_mad: float = 6.0            # band half-width in MADs
    scale_floor: float = 1e-6     # MAD floor: band never collapses to zero
    # median-of-means group count; 0 (default) = one group per value,
    # i.e. the plain median — maximal 50% breakdown point. A sorted-chunk
    # MoM with g groups tolerates only < g/2 colluders (a clique of k can
    # own up to k chunks), so lower this below the probe count only for
    # heavy-tailed loss-diffs at scale, knowingly trading breakdown point
    # for variance reduction.
    mom_groups: int = 0
    # -- per-record loss consistency (both lanes; the int8 "majority"
    #    channel: the fleet median is the consensus) --
    loss_k_mad: float = 8.0
    loss_floor: float = 5e-2      # absolute MAD floor for the loss band
    # -- quarantine state machine: persistent outliers are excluded --
    window: int = 4               # sliding window (steps) of outlier verdicts
    quarantine_after: int = 3     # verdicts within the window that trigger it
    quarantine_steps: int = 4     # exclusion length; 0 = permanent

    def __post_init__(self):
        if self.mode not in ("mask", "clip"):
            raise ValueError(f"robust mode {self.mode!r} not in mask|clip")
        if self.window < 1 or self.quarantine_after < 1:
            raise ValueError("quarantine window/threshold must be >= 1")
        if self.quarantine_after > self.window:
            raise ValueError("quarantine_after cannot exceed window")
        if self.k_mad <= 0 or self.loss_k_mad <= 0 or self.mom_groups < 0:
            raise ValueError("filter bands must be positive")


@dataclass(frozen=True)
class GossipConfig:
    """Leaderless topology knobs (fleet/gossip.py).

    Epidemic record exchange: each step, every active peer pushes the
    step-records it holds to ``fanout`` deterministically-chosen peers,
    ``rounds`` times; an anti-entropy ring sweep then runs the connected
    component to quiescence, so every peer of a component closes the
    step from the identical candidate multiset (what makes the
    leaderless commit bit-identical without consensus). Exchanges are
    digest-coordinated: a link carries only records the destination
    lacks (O(1) digest bytes are not modeled).

    ``partitions`` is a deterministic network-split schedule: triples
    ``(lo_step, hi_step, group_bitmask)`` — during steps [lo, hi) the
    fleet splits into the group and its complement; no record crosses.
    The side holding the strict majority of workers (tie: the side
    containing the highest worker id — the same leaderless tiebreak the
    commit rule uses) keeps committing; the minority stalls and
    reconciles by ledger replay at heal (docs/fleet.md, "Leaderless
    commits"). Windows must not overlap.
    """
    fanout: int = 2
    rounds: int = 2
    partitions: Tuple[Tuple[int, int, int], ...] = field(default=())

    def __post_init__(self):
        if self.fanout < 1 or self.rounds < 1:
            raise ValueError("gossip fanout and rounds must be >= 1")
        spans = []
        for lo, hi, group in self.partitions:
            if lo < 0 or hi <= lo:
                raise ValueError(f"partition window [{lo}, {hi}) is empty")
            if group <= 0:
                raise ValueError("partition group bitmask must be nonzero")
            spans.append((lo, hi))
        for (lo, hi), (lo2, hi2) in zip(sorted(spans), sorted(spans)[1:]):
            if lo2 < hi:
                raise ValueError("partition windows must not overlap")

    def active_partition(self, step: int) -> Optional[int]:
        """The group bitmask of the partition covering `step`, if any."""
        for lo, hi, group in self.partitions:
            if lo <= step < hi:
                return group
        return None


@dataclass(frozen=True)
class ByzantineSpec:
    """One simulated attacker: worker `worker` runs `attack` with
    strength `amp` (0.0 = the attack's lane-dependent default). Attack
    models live in fleet/adversary.py; tampering is a deterministic
    function of the honest record stream, so Byzantine chaos runs are
    reproducible fixtures like every other failure mode."""
    worker: int
    attack: str
    amp: float = 0.0


@dataclass(frozen=True)
class FleetConfig:
    num_workers: int = 8
    probes_per_worker: int = 1
    # -- transport chaos (deterministic in chaos_seed) --
    dropout: float = 0.0          # P(record lost on the worker->coord link)
    max_delay: int = 0            # record delivery delay, uniform [0, max]
    deadline: int = 0             # ticks the coordinator waits per step;
    #                               delivered-but-later records are
    #                               stragglers and get probe-masked
    chaos_seed: int = 0
    # -- catch-up / persistence --
    snapshot_every: int = 10      # coordinator keeps a full param snapshot
    #                               every N steps as a replay base
    local_ckpt_every: int = 0     # workers checkpoint locally (0 = off)
    # -- crash schedule: (worker_id, crash_step, down_steps) triples --
    crashes: Tuple[Tuple[int, int, int], ...] = field(default=())
    # -- Byzantine machinery: attackers (simulated, fleet/adversary.py)
    #    and the robust commit filter (fleet/robust.py; None = filter-free,
    #    exactly the pre-robust protocol) --
    byzantine: Tuple[ByzantineSpec, ...] = field(default=())
    robust: Optional[RobustConfig] = None
    # -- topology: "star" (coordinator closes every step) or "gossip"
    #    (leaderless: epidemic record exchange, every peer closes each
    #    step via the same deterministic commit rule) --
    topology: str = "star"
    gossip: Optional[GossipConfig] = None

    @property
    def n_probes(self) -> int:
        """Total probes per step across the fleet."""
        return self.num_workers * self.probes_per_worker

    def probe_block(self, worker: int):
        m = self.probes_per_worker
        return range(worker * m, (worker + 1) * m)

    def __post_init__(self):
        # raises, not asserts: topology/chaos validation must survive -O
        # (the Byzantine suites run once under PYTHONOPTIMIZE=1)
        if not 1 <= self.num_workers <= 32:
            raise ValueError("commit bitmask is u32: 1 <= num_workers <= 32")
        if not 1 <= self.probes_per_worker <= 255:
            raise ValueError("record probe count is u8")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError("dropout must be in [0, 1)")
        seen = set()
        for spec in self.byzantine:
            if not 0 <= spec.worker < self.num_workers:
                raise ValueError(f"byzantine worker {spec.worker} out of "
                                 f"range for {self.num_workers} workers")
            if spec.worker in seen:
                raise ValueError(f"worker {spec.worker} has two byzantine "
                                 "specs")
            seen.add(spec.worker)
        if len(seen) == self.num_workers and self.num_workers > 1:
            raise ValueError("at least one worker must stay honest")
        if self.topology not in ("star", "gossip"):
            raise ValueError(f"topology {self.topology!r} not in "
                             "star|gossip")
        if self.gossip is not None and self.topology != "gossip":
            raise ValueError("GossipConfig given but topology is "
                             f"{self.topology!r}")
        full = (1 << self.num_workers) - 1
        for lo, hi, group in (self.gossip.partitions
                              if self.gossip else ()):
            if group & ~full or group == full:
                raise ValueError(
                    f"partition group {group:#x} must name a proper "
                    f"nonempty subset of the {self.num_workers} workers")
        if self.robust is not None and self.n_probes > 255 * 8:
            # commit v2 stores the per-probe filter bitmask behind a u8
            # byte count: fail at construction, not mid-run serialization
            raise ValueError(
                f"robust filtering supports at most {255 * 8} probes "
                "(commit v2 filter-mask length is u8 bytes); got "
                f"{self.n_probes}")
