"""Fleet configuration: simulated edge-device swarm topology and chaos.

A fleet is W workers that jointly own the step's antithetic SPSA probes
(probe-parallel data distribution, docs/fleet.md): worker w evaluates the
contiguous probe block [w*m, (w+1)*m) on the step-deterministic batch and
publishes one ledger record. The chaos knobs drive the deterministic
in-process transport (fleet/transport.py) so dropout/straggler/crash
scenarios are reproducible test fixtures, not flaky integration tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class FleetConfig:
    num_workers: int = 8
    probes_per_worker: int = 1
    # -- transport chaos (deterministic in chaos_seed) --
    dropout: float = 0.0          # P(record lost on the worker->coord link)
    max_delay: int = 0            # record delivery delay, uniform [0, max]
    deadline: int = 0             # ticks the coordinator waits per step;
    #                               delivered-but-later records are
    #                               stragglers and get probe-masked
    chaos_seed: int = 0
    # -- catch-up / persistence --
    snapshot_every: int = 10      # coordinator keeps a full param snapshot
    #                               every N steps as a replay base
    local_ckpt_every: int = 0     # workers checkpoint locally (0 = off)
    # -- crash schedule: (worker_id, crash_step, down_steps) triples --
    crashes: Tuple[Tuple[int, int, int], ...] = field(default=())

    @property
    def n_probes(self) -> int:
        """Total probes per step across the fleet."""
        return self.num_workers * self.probes_per_worker

    def probe_block(self, worker: int):
        m = self.probes_per_worker
        return range(worker * m, (worker + 1) * m)

    def __post_init__(self):
        assert 1 <= self.num_workers <= 32, "commit bitmask is u32"
        assert 1 <= self.probes_per_worker <= 255, "record probe count is u8"
        assert 0.0 <= self.dropout < 1.0
