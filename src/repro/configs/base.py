"""Config dataclasses for models, shapes, training lanes, and meshes.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s.  A ``Cell`` = (arch, shape) is
the unit of the dry-run matrix.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Block kinds used in ``block_pattern`` (one scan period).
ATTN = "attn"
MAMBA = "mamba"
RWKV = "rwkv"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # -- attention details --
    qk_norm: bool = False
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 1_000_000.0
    # -- MoE --
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # -- block pattern: one scan period; default = all-attention --
    block_pattern: Tuple[str, ...] = ()
    # -- MoE interleave within a period: indices of MoE FFN positions.
    #    Empty + num_experts>0 means "every layer is MoE".
    moe_every: int = 1               # FFN is MoE when (layer_idx % moe_every)==moe_offset
    moe_offset: int = 0
    # -- SSM (mamba / rwkv6) --
    ssm_state_dim: int = 16          # mamba N
    ssm_expand: int = 2              # mamba d_inner = expand * d_model
    ssm_conv_width: int = 4
    rwkv_head_dim: int = 64
    # -- encoder-decoder (whisper) --
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub frontend)
    # -- VLM (llava) --
    num_image_tokens: int = 0        # precomputed patch embeddings (stub frontend)
    # -- misc --
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # long-context capable (sub-quadratic attention path): drives long_500k
    subquadratic: bool = False
    notes: str = ""

    # ---- derived ----
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern if self.block_pattern else (ATTN,)

    @property
    def num_periods(self) -> int:
        p = len(self.pattern)
        if self.num_layers % p:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} is not a "
                f"multiple of the {p}-block pattern")
        return self.num_layers // p

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, 256)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, ff, V = self.d_model, self.d_ff, self.padded_vocab
        n = V * d                                    # embed
        if not self.tie_embeddings:
            n += V * d                               # unembed
        hd = self.head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        dense_ffn = 3 * d * ff                       # SwiGLU
        if self.is_moe:
            e = self.experts_per_token if active_only else self.num_experts
            moe_ffn = e * 3 * d * ff + d * self.num_experts  # + router
        else:
            moe_ffn = dense_ffn
        d_inner = self.ssm_expand * d
        mamba = (d * 2 * d_inner                     # in_proj (x, z)
                 + d_inner * self.ssm_conv_width     # conv
                 + d_inner * (self.ssm_state_dim * 2 + d // 16)  # B,C,dt proj
                 + (d // 16) * d_inner               # dt up
                 + d_inner * self.ssm_state_dim      # A
                 + d_inner * d)                      # out proj
        # rwkv6: time-mix ~5 d² (r,k,v,g,o) + channel-mix (k: d->ff, v: ff->d, r: d->d)
        rwkv = 5 * d * d + (d * self.d_ff + self.d_ff * d + d * d)
        per_layer = 0
        for li in range(self.num_layers):
            kind = self.pattern[li % len(self.pattern)]
            if kind == ATTN:
                per_layer += attn
                per_layer += moe_ffn if (self.is_moe and li % self.moe_every == self.moe_offset) else dense_ffn
            elif kind == MAMBA:
                per_layer += mamba
                per_layer += moe_ffn if (self.is_moe and li % self.moe_every == self.moe_offset) else dense_ffn
            elif kind == RWKV:
                per_layer += rwkv
        n += per_layer
        if self.encoder_layers:
            n += self.encoder_layers * (attn + dense_ffn)   # encoder blocks
            n += self.num_layers * attn                     # cross-attention
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    long_context: bool = False


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode", long_context=True)
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class LaneConfig:
    """Training-lane hyperparameters (the paper's knobs)."""
    lane: str = "elastic_zo"          # full_bp | full_zo | elastic_zo | elastic_zo_int8
    bp_tail_layers: int = 1           # K;  C = L - K  (paper: last 1-2 FC layers)
    bp_unembed: bool = True           # LM head trained via BP (part of the tail)
    zo_eps: float = 1e-3
    zo_num_probes: int = 1            # antithetic pairs (multi-probe variance reduction)
    zo_clip: float = 100.0            # g-clipping (paper: clip to [-g_clip, g_clip])
    learning_rate: float = 1e-2
    tail_learning_rate: Optional[float] = None
    # the paper's schedule: lr *= factor every `every` steps (0 = constant)
    lr_decay_factor: float = 1.0
    lr_decay_every: int = 0
    bp_grad_mode: str = "avg_perturbed"   # avg_perturbed (Alg.1) | clean (3rd fwd)
    # fused antithetic pair: run theta+eps*z and theta-eps*z through the layer
    # stack together so FSDP weight gathers are paid once (beyond-paper;
    # EXPERIMENTS.md §Perf). elastic_zo lane only.
    fused_probes: bool = False
    # int8 lane (Alg. 2)
    int8_loss_mode: str = "int"       # int (INT8*, Eq. 7-12) | float (sgn of fp32 diff)
    int8_r_max: int = 3
    int8_p_zero: float = 0.33
    int8_b_zo: int = 1
    int8_b_bp: int = 5
    # distributed
    allow_partial_probes: bool = True
    compress_tail_grads: bool = False


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pattern = cfg.pattern
    small = dict(
        num_layers=len(pattern) if len(pattern) > 1 else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=4 if cfg.num_experts else 0,
        experts_per_token=2 if cfg.num_experts else 0,
        ssm_state_dim=8,
        rwkv_head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_seq else 0,
        num_image_tokens=8 if cfg.num_image_tokens else 0,
        sliding_window=16 if cfg.sliding_window else 0,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
