"""Config registry: ``get_arch(name)``, ``get_shape(name)``, cell matrix."""
from .base import (ATTN, MAMBA, RWKV, LaneConfig, ModelConfig, ShapeConfig,
                   SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
                   pad_to, reduced)
from .archs import ARCHS
from .fleet import ByzantineSpec, FleetConfig, GossipConfig, RobustConfig
from .paper_models import LENET5, POINTNET, POINTNET_SYN, LeNet5Config, PointNetConfig
from .serve import ServeConfig


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_matrix():
    """All (arch, shape) dry-run cells with skip annotations.

    Returns a list of (arch_name, shape_name, run: bool, reason: str).
    """
    cells = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            if s.long_context and not a.subquadratic:
                cells.append((a.name, s.name, False,
                              "pure full-attention arch; 500k dense KV cache "
                              "excluded per assignment (docs/design.md §6)"))
            else:
                cells.append((a.name, s.name, True, ""))
    return cells
