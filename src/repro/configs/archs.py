"""The 10 assigned architectures, exact published configs.

Sources are cited per-arch; shapes pairing per the assignment:
train_4k / prefill_32k / decode_32k always; long_500k only for
sub-quadratic archs (rwkv6, jamba, mixtral-SWA) — see docs/design.md §6.
"""
from .base import ATTN, MAMBA, RWKV, ModelConfig

MISTRAL_NEMO_12B = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1_000_000.0,
    notes="[hf:mistralai/Mistral-Nemo-Base-2407] 128k ctx, GQA kv=8",
)

PHI4_MINI_3_8B = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=200064, rope_theta=10_000.0,
    notes="[arXiv:2412.08905] RoPE SwiGLU GQA; 24 heads -> seq-shard attention TP fallback",
)

QWEN3_4B = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936, qk_norm=True, rope_theta=1_000_000.0,
    notes="[hf:Qwen/Qwen3-8B family] qk_norm, decoupled head_dim=128 (H*Dh != d_model)",
)

LLAMA3_8B = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500_000.0,
    notes="[arXiv:2407.21783] GQA, 128k vocab",
)

PHI35_MOE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    num_experts=16, experts_per_token=2, rope_theta=10_000.0,
    notes="[hf:microsoft/Phi-3.5-MoE-instruct] 16 experts top-2, every layer MoE",
)

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    num_experts=8, experts_per_token=2, sliding_window=4096,
    rope_theta=1_000_000.0, subquadratic=True,
    notes="[arXiv:2401.04088] 8e top-2, SWA(4096) => long_500k eligible (bounded KV)",
)

RWKV6_1_6B = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536, rwkv_head_dim=64,
    block_pattern=(RWKV,), subquadratic=True,
    notes="[arXiv:2404.05892] Finch: data-dependent decay; attention-free",
)

WHISPER_SMALL = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1500, rope_theta=0.0,  # learned abs pos
    notes="[arXiv:2212.04356] enc-dec; conv frontend stubbed as frame embeddings",
)

LLAVA_NEXT_34B = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    num_image_tokens=2880, rope_theta=5_000_000.0,
    notes="[hf:llava-hf family] anyres tiling stubbed: 2880 patch-embed tokens (5 tiles x 576)",
)

JAMBA_V01_52B = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    num_experts=16, experts_per_token=2,
    moe_every=2, moe_offset=1,       # every other layer MoE (Jamba paper)
    block_pattern=(MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA, MAMBA),
    ssm_state_dim=16, ssm_expand=2, ssm_conv_width=4, subquadratic=True,
    notes="[arXiv:2403.19887] attn:mamba 1:7, MoE every other layer, 16e top-2",
)

ARCHS = {c.name: c for c in (
    MISTRAL_NEMO_12B, PHI4_MINI_3_8B, QWEN3_4B, LLAMA3_8B, PHI35_MOE,
    MIXTRAL_8X7B, RWKV6_1_6B, WHISPER_SMALL, LLAVA_NEXT_34B, JAMBA_V01_52B,
)}
