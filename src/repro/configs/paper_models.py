"""The paper's own models: LeNet-5 (MNIST-like) and PointNet (point clouds).

These are the faithful-reproduction targets (Tables 1-2, Figs. 2-7) and are
defined separately from the LM ``ModelConfig`` since they are small convnets.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class LeNet5Config:
    name: str = "lenet5"
    in_shape: Tuple[int, int, int] = (28, 28, 1)
    conv_channels: Tuple[int, int] = (6, 16)
    kernel: int = 5
    fc_dims: Tuple[int, int, int] = (120, 84, 10)   # fc1, fc2, classifier
    num_classes: int = 10
    # layer list used for the partition point C (paper Fig. 1 top):
    #   conv1, conv2, fc1, fc2, fc3   (5 trainable layers)
    num_trainable_layers: int = 5


@dataclass(frozen=True)
class PointNetConfig:
    name: str = "pointnet"
    num_points: int = 1024
    # feature extraction: 5 pointwise FC layers (64,64,64,128,1024) + maxpool,
    # classification head: 3 FC (512, 256, num_classes)   (paper Fig. 1 bottom)
    feat_dims: Tuple[int, ...] = (64, 64, 64, 128, 1024)
    head_dims: Tuple[int, ...] = (512, 256)
    num_classes: int = 40
    num_trainable_layers: int = 8


LENET5 = LeNet5Config()
POINTNET = PointNetConfig()
# Smaller synthetic-data variant (8-class parametric shapes) used by tests.
POINTNET_SYN = PointNetConfig(num_classes=8, num_points=256)
