"""Serving-engine configuration (src/repro/serve/).

Sizing contract: the paged pool must be able to hold at least one
worst-case sequence (``ceil((max_seq_len + 1) / page_size)`` pages) or the
scheduler could deadlock; ``Engine`` validates this at construction and
``Scheduler.submit`` rejects requests that can never fit.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ServeConfig:
    page_size: int = 16          # tokens per KV page
    num_pages: int = 256         # pool pages per layer (page 0 = null page)
    max_batch_slots: int = 8     # decode batch width (continuous batching)
    max_seq_len: int = 512       # hard cap: prompt + generated (+ img tokens)
    max_new_tokens: int = 64     # default per-request generation budget
    bucket_prompts: bool = False  # pow2 prompt-length bucketing (attn-only
    #                               archs; SSM state would absorb pad tokens)
    eos_id: int = -1             # -1: never stop early
    megastep: int = 32           # max decode ticks fused into one device
    #                              call while the plan is provably steady
    #                              (Scheduler.steady_horizon); 1 disables

    @property
    def max_pages_per_seq(self) -> int:
        return -(-(self.max_seq_len + 1) // self.page_size)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold `tokens` cache entries."""
        return -(-tokens // self.page_size)
